"""Paged KV cache: pool invariants, kernel equivalence, engine parity.

- :class:`PagePool` alloc/free invariants (disjointness, exhaustion,
  accounting, snapshot restore);
- paged decode attention vs the ``ref.py`` oracle in both ``xla`` and
  ``pallas_interpret`` backends;
- the paged engine matching dense-engine outputs token-for-token where
  dense bucketing is exact, and matching an exact unpadded-prefill
  reference where it is not (chunked prefill is exact at any length);
- snapshot → restore round-trip mid-generation with paging enabled.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.kernels import ops, ref
from repro.models import get_model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagePool, pages_needed

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# PagePool invariants
# ---------------------------------------------------------------------------


def test_pool_alloc_free_invariants():
    pool = PagePool(16)
    assert pool.available == 15  # page 0 reserved
    a = pool.alloc(5)
    b = pool.alloc(7)
    assert 0 not in a + b
    assert len(set(a) & set(b)) == 0
    assert pool.available == 3
    assert pool.outstanding == 12
    assert pool.alloc(4) is None          # exhausted: no side effects
    assert pool.available == 3
    pool.free(a)
    assert pool.available == 8
    c = pool.alloc(8)
    assert len(set(c) & set(b)) == 0      # b still owned
    pool.free(b)
    pool.free(c)
    assert pool.available == 15
    assert pool.outstanding == 0


def test_pool_double_free_rejected():
    pool = PagePool(4)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(AssertionError):
        pool.free(pages)


def test_pool_restore():
    pool = PagePool(8)
    pool.alloc(3)
    free = list(pool._free)
    other = PagePool(8)
    other.restore(free)
    assert other.available == pool.available
    assert other.outstanding == pool.outstanding


def test_pages_needed():
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert pages_needed(0, 16) == 1  # at least one page


# ---------------------------------------------------------------------------
# Kernel: paged decode attention vs oracle
# ---------------------------------------------------------------------------


def _paged_case(b, h, k, d, page, max_pages, n_pages, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, d)), dtype)
    kp = jnp.asarray(RNG.standard_normal((n_pages, page, k, d)), dtype)
    vp = jnp.asarray(RNG.standard_normal((n_pages, page, k, d)), dtype)
    ids = RNG.permutation(np.arange(1, n_pages))[: b * max_pages]
    table = jnp.asarray(ids.reshape(b, max_pages), jnp.int32)
    lens = jnp.asarray(RNG.integers(1, max_pages * page + 1, b), jnp.int32)
    return q, kp, vp, table, lens


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
@pytest.mark.parametrize(
    "b,h,k,d,page,max_pages,n_pages",
    [(2, 4, 2, 16, 8, 4, 12), (3, 8, 8, 32, 16, 3, 16),
     (1, 16, 2, 64, 8, 5, 8)],
)
def test_paged_decode_attention(b, h, k, d, page, max_pages, n_pages,
                                backend, dtype):
    q, kp, vp, table, lens = _paged_case(b, h, k, d, page, max_pages,
                                         n_pages, dtype)
    want = ref.paged_decode_attention(q, kp, vp, table, lens)
    with ops.use_backend(backend):
        got = ops.paged_decode_attention(q, kp, vp, table, lens)
    tol = dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_paged_decode_attention_zero_length_lane():
    q, kp, vp, table, lens = _paged_case(2, 4, 2, 16, 8, 4, 12, jnp.float32)
    lens = lens.at[0].set(0)  # inactive slot: output must be zeros, not NaN
    with ops.use_backend("pallas_interpret"):
        got = ops.paged_decode_attention(q, kp, vp, table, lens)
    assert np.allclose(np.asarray(got)[0], 0.0)
    assert not np.any(np.isnan(np.asarray(got)))


# ---------------------------------------------------------------------------
# Engine parity + lifecycle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    cfg = REDUCED["qwen3-8b"]
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]


def _paged_engine(model, params, n_slots=2, **kw):
    kw.setdefault("max_seq", 96)
    kw.setdefault("page_size", 16)
    kw.setdefault("prefill_chunk", 32)
    return ServeEngine(model, params, n_slots=n_slots, paged=True, **kw)


def test_paged_matches_dense_token_for_token(qwen):
    """Power-of-two prompts: dense bucketing is exact, so the two engines
    must agree on every generated token."""
    cfg, model, params = qwen
    prompts = _prompts(cfg, [32, 64, 32, 64], seed=3)
    dense = ServeEngine(model, params, n_slots=2, max_seq=96, paged=False)
    paged = _paged_engine(model, params)
    for p in prompts:
        dense.submit(p, max_new_tokens=5)
        paged.submit(p, max_new_tokens=5)
    dd = sorted(dense.run(300), key=lambda r: r.req_id)
    pd = sorted(paged.run(300), key=lambda r: r.req_id)
    assert [r.generated for r in pd] == [r.generated for r in dd]


def test_chunked_prefill_exact_at_any_length(qwen):
    """Chunked prefill takes the true final prompt position (regression for
    the bucketed first-token bug) and pads nothing the model can see: the
    continuation equals an exact unpadded prefill + decode at every prompt
    length, including lengths that cross chunk boundaries."""
    cfg, model, params = qwen
    from repro.serving.kvcache import expand_prefill_cache

    def exact(p, n_new):
        logits, cache = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray([p], jnp.int32)}
        )
        out = [int(jnp.argmax(logits[0]))]
        cache = expand_prefill_cache(cache, model.init_cache(1, 96))
        dec = jax.jit(model.decode_step)
        pos = len(p)
        for _ in range(n_new - 1):
            lg, cache = dec(params, cache, {
                "tokens": jnp.asarray([[out[-1]]], jnp.int32),
                "positions": jnp.asarray([pos], jnp.int32),
            })
            out.append(int(jnp.argmax(lg[0])))
            pos += 1
        return out

    prompts = _prompts(cfg, [5, 11, 33, 40], seed=4)
    eng = _paged_engine(model, params)
    reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.run(300)
    for r, p in zip(reqs, prompts):
        assert r.generated == exact(p, 4), len(p)


def test_paged_engine_frees_pages_on_completion(qwen):
    cfg, model, params = qwen
    eng = _paged_engine(model, params, n_slots=2)
    usable = eng.pool.available
    reqs = [eng.submit(p, max_new_tokens=4)
            for p in _prompts(cfg, [8, 8, 8, 8], seed=5)]
    eng.step()
    assert eng.pool.outstanding > 0
    eng.run(300)
    assert all(r.done for r in reqs)
    assert eng.pool.available == usable
    assert eng.pool.outstanding == 0
    assert np.all(eng.page_table == 0)  # all rows back to the scratch page


def test_paged_pool_exhaustion_queues_requests(qwen):
    """An undersized pool must queue, not corrupt: every request still
    completes with the same tokens as an uncontended engine."""
    cfg, model, params = qwen
    prompts = _prompts(cfg, [32, 32, 32, 32], seed=6)
    big = _paged_engine(model, params, n_slots=2)
    small = _paged_engine(model, params, n_slots=2, n_pages=4)  # 3 usable
    for p in prompts:
        big.submit(p, max_new_tokens=5)
        small.submit(p, max_new_tokens=5)
    bd = sorted(big.run(400), key=lambda r: r.req_id)
    sd = sorted(small.run(400), key=lambda r: r.req_id)
    assert len(sd) == len(prompts)
    assert [r.generated for r in sd] == [r.generated for r in bd]


def test_paged_snapshot_restore_resumes_identically(qwen):
    """Mid-generation paged snapshot restored on a 'substitute host' must
    produce the same continuations (ad hoc continuity, paper §III-D)."""
    cfg, model, params = qwen
    prompts = _prompts(cfg, [8, 24, 40, 12], seed=7)

    ref_eng = _paged_engine(model, params)
    for p in prompts:
        ref_eng.submit(p, max_new_tokens=8)
    ref_done = sorted(ref_eng.run(400), key=lambda r: r.req_id)

    eng = _paged_engine(model, params)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    blob = eng.snapshot()
    eng2 = _paged_engine(model, params)
    eng2.restore(blob)
    done2 = sorted(eng2.run(400), key=lambda r: r.req_id)

    assert [r.generated for r in done2] == [r.generated for r in ref_done]
    # allocator state survived: finish everything, pool fully drains
    assert eng2.pool.outstanding == 0
    assert np.all(eng2.page_table == 0)


def test_paged_dense_snapshot_mode_mismatch_rejected(qwen):
    cfg, model, params = qwen
    paged = _paged_engine(model, params)
    blob = paged.snapshot()
    dense = ServeEngine(model, params, n_slots=2, max_seq=96, paged=False)
    with pytest.raises(AssertionError):
        dense.restore(blob)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b"])
def test_stateful_families_paged_serve(arch):
    """Chunked prefill writes recurrent state in place (dt=0 pad identity);
    paged serving of SSM/hybrid families completes and is deterministic."""
    cfg = REDUCED[arch]
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, n_slots=2, max_seq=64, paged=True,
                          page_size=16, prefill_chunk=16)
        reqs = [eng.submit(p, max_new_tokens=4)
                for p in _prompts(cfg, [6, 18, 9], seed=8)]
        done = sorted(eng.run(300), key=lambda r: r.req_id)
        assert len(done) == 3
        outs.append([tuple(r.generated) for r in done])
    assert outs[0] == outs[1]
