"""Serving engine: continuous batching, snapshot/restore determinism."""

import jax
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.models import get_model
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def qwen():
    cfg = REDUCED["qwen3-8b"]
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def prompts(cfg, n, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, length).tolist() for _ in range(n)]


def test_more_requests_than_slots_all_complete(qwen):
    cfg, model, params = qwen
    eng = ServeEngine(model, params, n_slots=3, max_seq=96)
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts(cfg, 8)]
    done = eng.run(500)
    assert len(done) == 8
    assert all(len(r.generated) == 6 for r in reqs)


def test_deterministic_across_engines(qwen):
    cfg, model, params = qwen
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, n_slots=2, max_seq=96)
        for p in prompts(cfg, 4, seed=3):
            eng.submit(p, max_new_tokens=5)
        done = sorted(eng.run(300), key=lambda r: r.req_id)
        outs.append([tuple(r.generated) for r in done])
    assert outs[0] == outs[1]


def test_snapshot_restore_resumes_identically(qwen):
    """A serving guest restored on a 'substitute host' must produce the
    same continuations (ad hoc continuity for inference jobs)."""
    cfg, model, params = qwen

    # uninterrupted reference
    ref_eng = ServeEngine(model, params, n_slots=2, max_seq=96)
    for p in prompts(cfg, 4, seed=7):
        ref_eng.submit(p, max_new_tokens=8)
    ref_done = sorted(ref_eng.run(400), key=lambda r: r.req_id)

    # interrupted at step 3, snapshotted, restored into a fresh engine
    eng = ServeEngine(model, params, n_slots=2, max_seq=96)
    for p in prompts(cfg, 4, seed=7):
        eng.submit(p, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    blob = eng.snapshot()
    eng2 = ServeEngine(model, params, n_slots=2, max_seq=96)
    eng2.restore(blob)
    done2 = sorted(eng2.run(400), key=lambda r: r.req_id)

    assert [r.generated for r in done2] == [r.generated for r in ref_done]


def test_eos_terminates_early(qwen):
    cfg, model, params = qwen
    eng = ServeEngine(model, params, n_slots=2, max_seq=96)
    # run once to learn what the first generated token will be
    probe = ServeEngine(model, params, n_slots=1, max_seq=96)
    p = prompts(cfg, 1, seed=9)[0]
    r0 = probe.submit(p, max_new_tokens=3)
    probe.run(50)
    eos = r0.generated[1] if len(r0.generated) > 1 else r0.generated[0]
    req = eng.submit(p, max_new_tokens=10, eos_id=eos)
    eng.run(100)
    assert req.done
    assert len(req.generated) <= 10
    assert req.generated[-1] == eos or len(req.generated) == 10


def test_snapshot_preserves_request_extra(qwen):
    """Regression: modality inputs (frames/embeds) in ``Request.extra`` must
    survive snapshot/restore — a restored engine replays queued multimodal
    prefills with their original arrays."""
    cfg, model, params = qwen
    eng = ServeEngine(model, params, n_slots=2, max_seq=96, paged=False)
    embeds = np.arange(12, dtype=np.float32).reshape(1, 3, 4)
    eng.submit(prompts(cfg, 1)[0], max_new_tokens=2,
               extra={"embeds": embeds})
    blob = eng.snapshot()
    eng2 = ServeEngine(model, params, n_slots=2, max_seq=96, paged=False)
    eng2.restore(blob)
    restored = eng2.queue[0].extra
    assert set(restored) == {"embeds"}
    np.testing.assert_array_equal(np.asarray(restored["embeds"]), embeds)
    assert restored["embeds"].dtype == embeds.dtype


def test_bucketed_prefill_samples_last_position():
    """Regression: when prefill returns full-sequence (B, S, V) logits, the
    first token must be sampled from the LAST position — under right-aligned
    bucketing position 0 is a pad row."""
    import jax.numpy as jnp

    S, V = 32, 7

    class StubFns:
        def init_cache(self, n_slots, max_seq, dtype):
            return {"k": jnp.zeros((1, n_slots, max_seq, 1, 1), dtype)}

        def prefill(self, params, batch):
            s = batch["tokens"].shape[1]
            logits = jnp.zeros((1, s, V))
            logits = logits.at[0, 0, 5].set(1.0)    # pad-row argmax: 5
            logits = logits.at[0, -1, 3].set(1.0)   # last-position argmax: 3
            return logits, {"k": jnp.zeros((1, 1, s, 1, 1), jnp.bfloat16)}

        decode_step = staticmethod(lambda *a: None)

    eng = ServeEngine(StubFns(), params=None, n_slots=1, max_seq=S,
                      paged=False)
    req = eng.submit(list(range(1, 9)), max_new_tokens=2)
    eng._admit()
    assert req.generated[0] == 3


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b"])
def test_stateful_families_serve(arch):
    cfg = REDUCED[arch]
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=2, max_seq=64)
    reqs = [eng.submit(p, max_new_tokens=4) for p in prompts(cfg, 3, 6)]
    done = eng.run(200)
    assert len(done) == 3
