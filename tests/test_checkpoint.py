"""Checkpoint subsystem: stores, replicated placement, restore."""

import numpy as np
import pytest

from repro.checkpoint.replicated import ReplicatedCheckpointManager
from repro.checkpoint.serializer import deserialize_tree, serialize_tree
from repro.checkpoint.store import DiskStore, SnapshotStore


def small_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": rng.standard_normal((8, 8)).astype(np.float32),
            "b": rng.standard_normal((8,)).astype(np.float32),
        },
        "opt": {"mu": rng.standard_normal((8, 8)).astype(np.float32),
                 "step": np.asarray(7, np.int32)},
        "rng": rng.integers(0, 2 ** 31, size=(2,)).astype(np.uint32),
    }


class TestStores:
    def test_put_get_delete(self):
        s = SnapshotStore()
        assert s.put("a", b"xyz")
        assert s.get("a") == b"xyz"
        assert "a" in s
        s.delete("a")
        assert s.get("a") is None

    def test_capacity_and_overwrite(self):
        s = SnapshotStore(capacity_bytes=10)
        assert s.put("a", b"12345")
        assert not s.put("b", b"1234567")     # would exceed 10 bytes
        assert s.put("a", b"1234567890")      # overwrite replaces, fits
        assert s.used_bytes == 10

    def test_keep_only_latest_semantics(self):
        s = SnapshotStore()
        s.put("job0", b"v1")
        s.put("job0", b"version-two")
        assert s.get("job0") == b"version-two"

    def test_disk_store_round_trip(self, tmp_path):
        d = DiskStore(str(tmp_path / "snaps"))
        d.put("job/0", b"abc")
        d2 = DiskStore(str(tmp_path / "snaps"))  # reload from disk
        assert d2.get("job/0") == b"abc"
        d2.delete("job/0")
        assert DiskStore(str(tmp_path / "snaps")).get("job/0") is None


class TestReplicatedManager:
    def make(self, hosts=("a", "b", "c", "d"), owners=("a", "b"), **kw):
        stores = {h: SnapshotStore() for h in hosts}
        mgr = ReplicatedCheckpointManager(
            "job0", list(owners), stores, **kw
        )
        return mgr, stores

    def fail_probs(self, hosts, p=0.05):
        return {h: p for h in hosts}

    def test_save_and_restore(self):
        mgr, stores = self.make()
        state = small_state()
        rec = mgr.save(
            state, step=13,
            fail_prob=self.fail_probs(stores),
            available=set(stores),
        )
        assert rec.complete
        out = mgr.restore(state, surviving=set(stores))
        assert out is not None
        got, step = out
        assert step == 13
        np.testing.assert_array_equal(got["params"]["w"],
                                      state["params"]["w"])

    def test_restore_survives_owner_loss(self):
        mgr, stores = self.make()
        state = small_state()
        mgr.save(state, 5, fail_prob=self.fail_probs(stores),
                 available=set(stores))
        surviving = {"c", "d"}           # both owners died
        if mgr.survival_ok(surviving):
            got, _ = mgr.restore(state, surviving=surviving)
            np.testing.assert_array_equal(got["opt"]["mu"],
                                          state["opt"]["mu"])

    def test_restore_fails_when_all_replicas_lost(self):
        mgr, stores = self.make(hosts=("a", "b"), owners=("a", "b"))
        state = small_state()
        mgr.save(state, 5, fail_prob=self.fail_probs(stores),
                 available=set(stores))
        assert mgr.restore(state, surviving=set()) is None
        assert not mgr.survival_ok(set())

    def test_drop_host_and_forget(self):
        mgr, stores = self.make()
        state = small_state()
        mgr.save(state, 5, fail_prob=self.fail_probs(stores),
                 available=set(stores))
        mgr.drop_host("a")
        for pl in mgr.latest.placements:
            assert "a" not in pl.receivers
        mgr.forget()
        assert mgr.latest is None
        assert all(s.used_bytes == 0 for h, s in stores.items())

    def test_sharding_balances_bytes(self):
        mgr, stores = self.make(owners=("a", "b", "c"))
        state = small_state()
        from repro.checkpoint.serializer import split_into_shards

        blobs = split_into_shards(state, 3)
        sizes = sorted(len(b) for b in blobs)
        assert sizes[-1] <= sizes[0] * 3 + 512   # roughly balanced


class TestSerializerEdgeCases:
    def test_scalar_and_empty_shapes(self):
        tree = {"s": np.asarray(3.5, np.float32),
                "z": np.zeros((0, 4), np.int32)}
        out = deserialize_tree(serialize_tree(tree), tree)
        assert float(out["s"]) == 3.5
        assert out["z"].shape == (0, 4)

    def test_wrong_structure_rejected(self):
        tree = {"a": np.zeros(3, np.float32)}
        blob = serialize_tree(tree)
        with pytest.raises((KeyError, AssertionError)):
            deserialize_tree(blob, {"b": np.zeros(3, np.float32)})

    def test_no_pickle_in_format(self):
        blob = serialize_tree({"a": np.zeros(3, np.float32)})
        assert b"pickle" not in blob
        assert blob[4:5] == b"["  # JSON header right after length
