"""Data pipeline: determinism + cursor-checkpoint semantics."""

import numpy as np

from repro.configs import REDUCED
from repro.data.synthetic import SyntheticDataset


def test_batch_is_pure_function_of_step():
    cfg = REDUCED["qwen3-8b"]
    ds1 = SyntheticDataset(cfg, 32, 4, seed=5)
    ds2 = SyntheticDataset(cfg, 32, 4, seed=5)
    for step in (0, 3, 17):
        b1, b2 = ds1.batch(step), ds2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_restore_mid_stream_is_exact():
    """Reading steps [k..n) after a 'restore' equals reading them straight
    through — the property the trainer's bit-exact resume relies on."""
    cfg = REDUCED["smollm-360m"]
    ds = SyntheticDataset(cfg, 16, 2, seed=1)
    straight = [ds.batch(i)["tokens"] for i in range(6)]
    restored = SyntheticDataset(cfg, 16, 2, seed=1)
    resumed = [restored.batch(i)["tokens"] for i in range(3, 6)]
    for a, b in zip(straight[3:], resumed):
        np.testing.assert_array_equal(a, b)


def test_steps_differ_and_labels_shift():
    cfg = REDUCED["qwen3-8b"]
    ds = SyntheticDataset(cfg, 64, 2, seed=0)
    b0, b1 = ds.batch(0), ds.batch(1)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are the next-token shift of the same underlying stream
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_learnable_structure():
    """Affine-recurrence streams have low conditional entropy: the same
    (prev -> next) mapping repeats within a row."""
    cfg = REDUCED["smollm-360m"]
    ds = SyntheticDataset(cfg, 512, 1, seed=2, noise=0.0)
    b = ds.batch(0)
    toks, labels = b["tokens"][0], b["labels"][0]
    mapping = {}
    consistent = 0
    for t, l in zip(toks, labels):
        if t in mapping:
            consistent += mapping[t] == l
        mapping[t] = l
    repeats = sum(1 for t in set(toks) if list(toks).count(t) > 1)
    if repeats:
        assert consistent > 0


def test_modality_extras():
    vcfg = REDUCED["llava-next-mistral-7b"]
    ds = SyntheticDataset(vcfg, 32, 2, seed=0)
    b = ds.batch(0)
    assert b["embeds"].shape == (2, vcfg.n_image_tokens, 1024)
    assert b["tokens"].shape == (2, 32 - vcfg.n_image_tokens)

    wcfg = REDUCED["whisper-medium"]
    ds = SyntheticDataset(wcfg, 32, 2, seed=0)
    b = ds.batch(0)
    assert b["frames"].shape == (2, 32, wcfg.d_model)
