"""Discrete-event simulation substrate + failure traces."""

import pytest

from repro.core.events import (
    FailureTrace,
    constant_failure_trace,
    nagios_like_trace,
    replay,
)
from repro.core.simulation import EventLoop, SimClock


class TestEventLoop:
    def test_ordered_execution(self):
        loop = EventLoop(SimClock())
        seen = []
        loop.schedule(5.0, lambda: seen.append("b"))
        loop.schedule(1.0, lambda: seen.append("a"))
        loop.schedule(5.0, lambda: seen.append("c"))  # ties: insertion order
        loop.run_until(10.0)
        assert seen == ["a", "b", "c"]
        assert loop.clock.now() == 10.0

    def test_periodic(self):
        loop = EventLoop(SimClock())
        ticks = []
        loop.every(10.0, lambda: ticks.append(loop.clock.now()))
        loop.run_until(35.0)
        assert ticks == [10.0, 20.0, 30.0]

    def test_cancel(self):
        loop = EventLoop(SimClock())
        ticks = []
        ev = loop.every(1.0, lambda: ticks.append(1))
        loop.run_until(2.5)
        loop.cancel(ev)
        loop.run_until(10.0)
        assert len(ticks) == 2

    def test_events_scheduled_during_run(self):
        loop = EventLoop(SimClock())
        seen = []
        loop.schedule(1.0, lambda: loop.schedule(1.0, lambda: seen.append("x")))
        loop.run_until(3.0)
        assert seen == ["x"]


class TestTraces:
    def test_deterministic(self):
        t1 = nagios_like_trace(10, 3600.0, seed=7)
        t2 = nagios_like_trace(10, 3600.0, seed=7)
        assert t1.events == t2.events
        t3 = nagios_like_trace(10, 3600.0, seed=8)
        assert t1.events != t3.events

    def test_alternating_and_in_range(self):
        tr = nagios_like_trace(20, 3600.0, seed=0)
        for h in tr.host_ids:
            evs = tr.for_host(h)
            assert all(0 <= e.t < 3600.0 for e in evs)
            for a, b in zip(evs, evs[1:]):
                assert a.kind != b.kind      # strict down/up alternation
            if evs:
                assert evs[0].kind == "down"  # hosts start UP

    def test_downtime_fraction(self):
        tr = constant_failure_trace(["h"], {"h": [100.0]}, 1000.0,
                                    recovery=100.0)
        assert tr.downtime_fraction("h") == pytest.approx(0.1)
        assert tr.n_failures("h") == 1

    def test_json_round_trip(self):
        tr = nagios_like_trace(5, 600.0, seed=3)
        tr2 = FailureTrace.from_json(tr.to_json())
        assert tr2.events == tr.events
        assert tr2.host_ids == tr.host_ids

    def test_replay_order_and_horizon(self):
        tr = nagios_like_trace(10, 3600.0, seed=1)
        seen = []
        for _ in replay(tr, seen.append, until=1800.0):
            pass
        assert seen == [e for e in tr.events if e.t < 1800.0]
        assert all(a.t <= b.t for a, b in zip(seen, seen[1:]))
