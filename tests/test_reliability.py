"""Unit tests for the paper's host_reliability formula (§III-B)."""

import pytest

from repro.core.reliability import (
    HostRecord,
    ReliabilityRegistry,
    host_reliability,
)


class TestFormula:
    def test_fresh_host_is_fully_reliable(self):
        # NF == 0 -> 100, even with no assignments yet
        assert host_reliability(0, 0, 0) == 100.0

    def test_no_failures_always_100(self):
        assert host_reliability(10, 10, 0) == 100.0
        assert host_reliability(10, 3, 0) == 100.0  # still running some

    def test_all_assignments_failed(self):
        # NF == CA -> 0
        assert host_reliability(5, 0, 5) == 0.0
        assert host_reliability(1, 0, 1) == 0.0

    def test_partial(self):
        # otherwise (CC/CA)*100
        assert host_reliability(10, 9, 1) == 90.0
        assert host_reliability(4, 2, 1) == 50.0
        assert host_reliability(3, 1, 2) == pytest.approx(100 / 3)

    def test_idle_failures(self):
        # failures before any assignment (outside the paper's formula):
        # treated like the NF==CA case
        assert host_reliability(0, 0, 3) == 0.0

    def test_nf_exceeding_ca_capped(self):
        # NF can exceed CA (host failures are not per-assignment);
        # reliability stays CC/CA
        assert host_reliability(4, 3, 5) == 75.0

    def test_zero_denominator_combinations(self):
        # every CA == 0 shape resolves without dividing by zero
        assert host_reliability(0, 0, 0) == 100.0   # fresh host
        assert host_reliability(0, 0, 1) == 0.0     # died while idle
        assert host_reliability(0, 2, 0) == 100.0   # NF == 0 wins
        assert host_reliability(0, 2, 3) == 0.0

    def test_overcounted_completions_clamped(self):
        # CC > CA (double-reported completion) must not exceed 100
        assert host_reliability(2, 5, 1) == 100.0

    def test_negative_counters_rejected(self):
        for bad in [(-1, 0, 0), (0, -1, 0), (0, 0, -1), (-2, -2, -2)]:
            with pytest.raises(ValueError):
                host_reliability(*bad)

    def test_score_always_in_range(self):
        for ca in range(4):
            for cc in range(4):
                for nf in range(4):
                    assert 0.0 <= host_reliability(ca, cc, nf) <= 100.0


class TestRecord:
    def test_nf_sums_host_and_guest_failures(self):
        r = HostRecord("h", jobs_assigned=4, jobs_completed=2,
                       host_failures=1, guest_failures=1)
        assert r.nf == 2
        assert r.reliability() == 50.0
        assert r.failure_probability() == pytest.approx(0.5)

    def test_storage(self):
        r = HostRecord("h", storage_used=10, storage_limit=10)
        assert r.storage_full()
        r.storage_limit = 11
        assert not r.storage_full()

    def test_failure_probability_clamped_to_unit_interval(self):
        # CC > CA would make 1 - rel/100 dip below 0 without the clamp
        r = HostRecord("h", jobs_assigned=2, jobs_completed=5,
                       host_failures=1)
        assert r.failure_probability() == 0.0
        for ca, cc, nf in [(0, 0, 0), (0, 0, 2), (3, 1, 2), (5, 0, 5)]:
            r = HostRecord("h", jobs_assigned=ca, jobs_completed=cc,
                           guest_failures=nf)
            assert 0.0 <= r.failure_probability() <= 1.0


class TestRegistry:
    def test_lifecycle(self):
        reg = ReliabilityRegistry()
        reg.add_host("a")
        reg.record_assignment("a")
        reg.record_completion("a")
        assert reg.reliability("a") == 100.0
        reg.record_assignment("a")
        reg.record_host_failure("a")
        assert reg.reliability("a") == 50.0

    def test_ranked_descending_with_stable_ties(self):
        reg = ReliabilityRegistry()
        for h, (ca, cc, hf) in {
            "a": (4, 2, 2), "b": (4, 3, 1), "c": (0, 0, 0), "d": (4, 3, 1),
        }.items():
            reg.add_host(h)
            for _ in range(ca):
                reg.record_assignment(h)
            for _ in range(cc):
                reg.record_completion(h)
            for _ in range(hf):
                reg.record_host_failure(h)
        assert reg.ranked() == ["c", "b", "d", "a"]
        assert reg.ranked(["a", "b"]) == ["b", "a"]

    def test_state_round_trip(self):
        reg = ReliabilityRegistry()
        reg.add_host("a")
        reg.record_assignment("a")
        reg.record_guest_failure("a")
        reg2 = ReliabilityRegistry.from_state(reg.to_state())
        assert reg2.reliability("a") == reg.reliability("a")
        assert reg2.get("a").guest_failures == 1


class TestQuarantine:
    def test_corrupt_result_lowers_score(self):
        reg = ReliabilityRegistry()
        reg.record_assignment("a")
        reg.record_corrupt_result("a", now=0.0)
        rec = reg.get("a")
        assert rec.corrupt_results == 1
        assert rec.guest_failures == 1
        assert reg.reliability("a") == 0.0

    def test_quarantine_after_threshold_with_growing_windows(self):
        reg = ReliabilityRegistry(quarantine_after=2, quarantine_base_s=10.0)
        reg.add_host("a")
        reg.record_corrupt_result("a", now=0.0)
        assert not reg.is_quarantined("a", 0.0)      # below threshold
        reg.record_corrupt_result("a", now=5.0)      # 2nd: base window
        assert reg.is_quarantined("a", 5.0)
        assert not reg.is_quarantined("a", 15.1)     # 5 + 10 elapsed
        reg.record_corrupt_result("a", now=20.0)     # 3rd: doubled window
        assert reg.is_quarantined("a", 39.0)
        assert not reg.is_quarantined("a", 40.1)

    def test_unknown_host_is_not_quarantined(self):
        assert not ReliabilityRegistry().is_quarantined("ghost", 1e9)

    def test_quarantine_state_round_trips(self):
        reg = ReliabilityRegistry(quarantine_after=1)
        reg.record_corrupt_result("a", now=3.0)
        reg2 = ReliabilityRegistry.from_state(reg.to_state())
        assert reg2.get("a").corrupt_results == 1
        assert reg2.get("a").quarantined_until == \
            reg.get("a").quarantined_until
