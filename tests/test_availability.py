"""Availability checker: the paper's 2-minute rule (§III-A)."""

from repro.core.availability import AvailabilityChecker


def test_host_fails_after_timeout():
    ac = AvailabilityChecker(failure_timeout=120.0)
    ac.record_poll("h", 0.0)
    assert ac.check(60.0) == []            # polled 60 s ago: fine
    assert ac.check(120.0) == []           # exactly at the boundary: fine
    assert ac.check(121.0) == ["h"]        # over 2 min silent: failed
    assert not ac.is_available("h")
    assert ac.check(200.0) == []           # only reported once


def test_poll_resets_the_clock_and_revives():
    ac = AvailabilityChecker(failure_timeout=120.0)
    ac.record_poll("h", 0.0)
    ac.record_poll("h", 100.0)
    assert ac.check(219.0) == []
    assert ac.check(221.0) == ["h"]
    ac.record_poll("h", 300.0)             # host came back
    assert ac.is_available("h")
    assert ac.available_hosts() == ["h"]


def test_multiple_hosts_independent():
    ac = AvailabilityChecker(failure_timeout=120.0)
    ac.record_poll("a", 0.0)
    ac.record_poll("b", 50.0)
    assert ac.check(130.0) == ["a"]
    assert ac.available_hosts() == ["b"]


def test_state_round_trip():
    ac = AvailabilityChecker()
    ac.record_poll("a", 5.0)
    ac.record_poll("b", 6.0)
    ac.check(1000.0)
    ac2 = AvailabilityChecker.from_state(ac.to_state())
    assert set(ac2.available_hosts()) == set(ac.available_hosts())
