"""Copy-on-write prefix sharing: pool refcounts, the prefix trie, and the
prefix-aware serving engine.

- :class:`PagePool` share/free refcount invariants, revival of cached
  pages out of the free list, validated snapshot restore;
- :class:`PrefixIndex` longest-prefix lookup, first-wins insert, subtree
  eviction, serialize/load round-trip;
- engine parity: sharing is exact (token-for-token vs the non-shared
  paged path), pages drain back to the initial free count (no refcount
  leaks), COW triggers on whole-prompt hits, the prefix-aware scheduler
  admits a cached-prefix request past a too-big FIFO head, and
  recurrent-state families fall back to trie bookkeeping only;
- snapshot/restore mid-flight with shared pages: refcounts and the trie
  round-trip, and no page is double-freed on release.
"""

import jax
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.models import get_model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagePool, PrefixIndex

PAGE = 16


# ---------------------------------------------------------------------------
# PagePool refcounts
# ---------------------------------------------------------------------------


def test_pool_share_refcounts():
    pool = PagePool(8)
    a = pool.alloc(3)
    pool.share(a[:2])
    assert pool.refcount(a[0]) == 2 and pool.refcount(a[2]) == 1
    assert pool.outstanding == 3
    pool.free(a)                      # drop the alloc refs
    assert pool.outstanding == 2      # shared pair still live
    assert pool.available == 5
    pool.free(a[:2])
    assert pool.outstanding == 0 and pool.available == 7


def test_pool_share_revives_cached_page():
    pool = PagePool(8)
    a = pool.alloc(2)
    pool.free(a)                      # back in the free list, content intact
    assert pool.available == 7
    pool.share(a)                     # prefix hit on a completed request
    assert pool.available == 5
    assert all(pool.refcount(p) == 1 for p in a)
    pool.free(a)
    assert pool.available == 7 and pool.outstanding == 0


def test_pool_overfree_rejected_through_sharing():
    pool = PagePool(8)
    a = pool.alloc(1)
    pool.share(a)
    pool.free(a)
    pool.free(a)
    with pytest.raises(AssertionError):
        pool.free(a)


@pytest.mark.parametrize("free,ref", [
    ([1, 1, 2], None),                # duplicate free ids
    ([0, 2], None),                   # scratch page in the free list
    ([9], None),                      # out of range
    ([1, 2], {"3": 0}),               # non-positive refcount
    ([1, 2, 3], {"3": 1}),            # page both free and refcounted
    ([1, 2], {"9": 1}),               # refcounted page out of range
    ([1, 2], {"3": 1}),               # pages missing entirely (4..7)
])
def test_pool_restore_rejects_corrupt_snapshots(free, ref):
    pool = PagePool(8)
    with pytest.raises(ValueError):
        pool.restore(free, ref)


def test_pool_restore_with_refcounts():
    pool = PagePool(8)
    pool.restore([1, 2, 3], {"4": 1, "5": 2, "6": 1, "7": 3})
    assert pool.available == 3 and pool.outstanding == 4
    assert pool.refcount(5) == 2
    pool.free([5])
    assert pool.refcount(5) == 1


def test_pool_restore_legacy_infers_exclusive_ownership():
    pool = PagePool(8)
    pool.restore([2, 4, 6])
    assert pool.outstanding == 4
    assert all(pool.refcount(p) == 1 for p in (1, 3, 5, 7))


# ---------------------------------------------------------------------------
# PrefixIndex
# ---------------------------------------------------------------------------


def _toks(*blocks):
    out = []
    for b in blocks:
        out.extend([b] * 4)
    return out


def test_prefix_index_lookup_and_insert():
    idx = PrefixIndex(4)
    idx.insert(_toks(1, 2, 3), [10, 11, 12])
    assert idx.lookup(_toks(1, 2, 3)) == [10, 11, 12]
    assert idx.lookup(_toks(1, 2) + [3, 3, 3]) == [10, 11]  # partial page
    assert idx.lookup(_toks(9, 2, 3)) == []
    # divergent tail shares the common prefix nodes
    idx.insert(_toks(1, 2, 7), [10, 11, 13])
    assert idx.lookup(_toks(1, 2, 7)) == [10, 11, 13]
    # first insert wins: a COW duplicate never displaces the original
    idx.insert(_toks(1, 2, 3), [20, 21, 22])
    assert idx.lookup(_toks(1, 2, 3)) == [10, 11, 12]


def test_prefix_index_evict_drops_subtree():
    idx = PrefixIndex(4)
    idx.insert(_toks(1, 2, 3), [10, 11, 12])
    idx.insert(_toks(1, 2, 7), [10, 11, 13])
    idx.evict_pages([11])
    assert idx.lookup(_toks(1, 2, 3)) == [10]
    assert idx.lookup(_toks(1, 2, 7)) == [10]
    # descendants of the evicted node are unreachable and dropped too
    assert 12 not in idx._nodes and 13 not in idx._nodes
    assert len(idx) == 1


def test_prefix_index_serialize_round_trip():
    idx = PrefixIndex(4)
    idx.insert(_toks(1, 2, 3), [10, 11, 12])
    idx.insert(_toks(1, 5), [10, 14])
    clone = PrefixIndex.load(4, idx.serialize())
    assert clone.lookup(_toks(1, 2, 3)) == [10, 11, 12]
    assert clone.lookup(_toks(1, 5)) == [10, 14]
    assert len(clone) == len(idx)


# ---------------------------------------------------------------------------
# Engine: sharing parity, COW, scheduler, snapshot
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    cfg = REDUCED["qwen3-8b"]
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 96)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("prefill_chunk", 32)
    return ServeEngine(model, params, paged=True, **kw)


def _shared_prompts(cfg, prefix_len, suffix_lens, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, prefix_len).tolist()
    return [prefix + rng.integers(1, cfg.vocab_size, n).tolist()
            for n in suffix_lens]


def test_sharing_matches_non_shared_token_for_token(qwen):
    cfg, model, params = qwen
    prompts = _shared_prompts(cfg, 32, [8, 8, 8, 8], seed=1)
    base = _engine(model, params, prefix_share=False)
    shared = _engine(model, params, prefix_share=True)
    for p in prompts:
        base.submit(p, max_new_tokens=5)
        shared.submit(p, max_new_tokens=5)
    bd = sorted(base.run(300), key=lambda r: r.req_id)
    sd = sorted(shared.run(300), key=lambda r: r.req_id)
    assert [r.generated for r in sd] == [r.generated for r in bd]
    assert shared.stats["prefill_tokens_shared"] > 0
    assert base.stats["prefill_tokens_shared"] == 0
    assert (shared.stats["prefill_tokens"]
            < base.stats["prefill_tokens"])
    # no refcount leaks: the pool drains back to its initial free count
    assert shared.pool.outstanding == 0
    assert shared.pool.available == shared.n_pages - 1
    assert np.all(shared.page_table == 0)


def test_sharing_survives_request_completion(qwen):
    """The trie caches prefixes of *completed* requests: their pages stay
    content-intact in the free list and are revived on the next hit."""
    cfg, model, params = qwen
    prompts = _shared_prompts(cfg, 32, [4, 6], seed=2)
    eng = _engine(model, params, n_slots=1)   # strictly sequential slots
    r1 = eng.submit(prompts[0], max_new_tokens=4)
    eng.run(300)
    assert eng.pool.outstanding == 0          # first request fully released
    r2 = eng.submit(prompts[1], max_new_tokens=4)
    eng.run(300)
    assert eng.stats["prefill_tokens_shared"] == 32  # revived, not recomputed
    base = _engine(model, params, n_slots=1, prefix_share=False)
    q1 = base.submit(prompts[0], max_new_tokens=4)
    base.run(300)
    q2 = base.submit(prompts[1], max_new_tokens=4)
    base.run(300)
    assert r1.generated == q1.generated and r2.generated == q2.generated


def test_whole_prompt_hit_triggers_cow(qwen):
    """An identical prompt whose length is page-aligned matches every full
    page; the final token is recomputed for first-token logits, which
    copies the partially-reused shared page instead of writing into it."""
    cfg, model, params = qwen
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, 2 * PAGE).tolist()
    eng = _engine(model, params)
    r1 = eng.submit(prompt, max_new_tokens=4)
    r2 = eng.submit(prompt, max_new_tokens=4)
    eng.run(300)
    assert eng.stats["cow_copies"] == 1
    assert r1.generated == r2.generated
    base = _engine(model, params, prefix_share=False)
    q = base.submit(prompt, max_new_tokens=4)
    base.run(300)
    assert r2.generated == q.generated
    assert eng.pool.outstanding == 0
    assert eng.pool.available == eng.n_pages - 1


def test_prefix_aware_admission_skips_oversized_head(qwen):
    """Under page pressure the scheduler admits a queued request whose
    cached prefix shrinks its private-page need, while the FIFO head
    waits — and the head still completes once capacity frees up."""
    cfg, model, params = qwen
    rng = np.random.default_rng(4)
    prefix = rng.integers(1, cfg.vocab_size, 2 * PAGE).tolist()
    a = prefix + rng.integers(1, cfg.vocab_size, 4).tolist()
    big = rng.integers(1, cfg.vocab_size, 64).tolist()
    c = prefix + rng.integers(1, cfg.vocab_size, 8).tolist()

    eng = _engine(model, params, n_pages=8)   # 7 usable pages
    ra = eng.submit(a, max_new_tokens=8)      # needs 3 pages
    eng.step()                                # A admitted, 4 pages free
    rb = eng.submit(big, max_new_tokens=16)   # needs 5 > 4: must wait
    rc = eng.submit(c, max_new_tokens=8)      # needs 3, but shares 2
    eng.step()
    assert rc.slot is not None                # admitted past the head
    assert rb.slot is None and rb in eng.queue
    done = eng.run(500)
    assert {r.req_id for r in done} == {ra.req_id, rb.req_id, rc.req_id}
    # the skipped head's output is unaffected by having waited
    ref = _engine(model, params, n_pages=8)
    qb = ref.submit(big, max_new_tokens=16)
    ref.run(300)
    assert rb.generated == qb.generated
    assert eng.pool.outstanding == 0
    assert eng.pool.available == 7


def test_admission_stays_fifo_without_a_cached_prefix(qwen):
    """Skipping the head is reserved for cached-prefix requests: a later
    request with no trie hit must wait behind an oversized head even when
    it would fit, preserving PR 1's FIFO liveness guarantee."""
    cfg, model, params = qwen
    rng = np.random.default_rng(8)
    a = rng.integers(1, cfg.vocab_size, 32).tolist()
    big = rng.integers(1, cfg.vocab_size, 64).tolist()
    small = rng.integers(1, cfg.vocab_size, 8).tolist()

    eng = _engine(model, params, n_pages=8)   # 7 usable pages
    eng.submit(a, max_new_tokens=8)           # 3 pages
    eng.step()                                # admitted: 4 free
    rb = eng.submit(big, max_new_tokens=16)   # needs 5 > 4: waits
    rc = eng.submit(small, max_new_tokens=8)  # would fit, but no prefix hit
    eng.step()
    assert rc.slot is None and rb.slot is None   # both behind the head
    done = eng.run(500)
    assert len(done) == 3                        # and everyone completes
    assert eng.pool.outstanding == 0


def test_failed_admission_retries_do_not_inflate_stats():
    """A queued request retried every step while the pool is full must
    not bump the would-be-hit counters on each failed attempt."""
    cfg = REDUCED["falcon-mamba-7b"]
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(9)
    prefix = rng.integers(1, cfg.vocab_size, 32).tolist()
    p1 = prefix + rng.integers(1, cfg.vocab_size, 4).tolist()
    p2 = prefix + rng.integers(1, cfg.vocab_size, 6).tolist()
    eng = ServeEngine(model, params, n_slots=2, max_seq=64, paged=True,
                      page_size=PAGE, prefill_chunk=16, n_pages=4)
    eng.submit(p1, max_new_tokens=8)          # 3 pages: fills the pool
    eng.submit(p2, max_new_tokens=8)          # hit, but must wait
    for _ in range(4):                        # several failed retries
        eng.step()
    assert eng.stats["prefix_hits"] <= 1      # not one per retry
    eng.run(300)
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_hit_tokens"] == 32
    assert eng.pool.outstanding == 0


def test_trie_load_rejects_corrupt_entries():
    from repro.serving.kvcache import PrefixIndex as PI
    with pytest.raises(ValueError):
        PI.load(4, [[0, -2, [1, 2, 3, 4]]])            # scratch page id
    with pytest.raises(ValueError):
        PI.load(4, [[9, -2, [1, 2, 3, 4]]], max_page=8)  # beyond the pool
    with pytest.raises(ValueError):
        PI.load(4, [[3, -2, [1, 2]]])                  # short block
    with pytest.raises(ValueError):                    # duplicate node id:
        PI.load(4, [[3, -2, [1, 2, 3, 4]],             # would leave a
                    [3, -2, [5, 6, 7, 8]]])            # dangling edge
    idx = PI.load(4, [[9, -2, [1, 2, 3, 4]]])          # phantom id: fine
    assert idx.lookup([1, 2, 3, 4]) == [9]


def test_snapshot_restores_shared_refcounts_and_trie(qwen):
    """Mid-generation snapshot with in-flight shared pages: refcounts and
    the trie round-trip, continuations replay identically, and releasing
    every request returns the pool to its initial free count without any
    double-free."""
    cfg, model, params = qwen
    prompts = _shared_prompts(cfg, 32, [4, 6, 9, 5], seed=5)

    ref_eng = _engine(model, params)
    for p in prompts:
        ref_eng.submit(p, max_new_tokens=8)
    ref_done = sorted(ref_eng.run(400), key=lambda r: r.req_id)

    eng = _engine(model, params)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    assert any(r > 1 for r in eng.pool._ref.values())   # sharing in flight
    ref_before = dict(eng.pool._ref)
    blob = eng.snapshot()

    eng2 = _engine(model, params)
    eng2.restore(blob)
    assert eng2.pool._ref == ref_before
    assert len(eng2.prefix_index) == len(eng.prefix_index)
    done2 = sorted(eng2.run(400), key=lambda r: r.req_id)
    assert [r.generated for r in done2] == [r.generated for r in ref_done]
    # releasing everything drains the pool exactly once per reference
    assert eng2.pool.outstanding == 0
    assert eng2.pool.available == eng2.n_pages - 1
    assert np.all(eng2.page_table == 0)


def test_sharing_disabled_restores_legacy_behavior(qwen):
    cfg, model, params = qwen
    prompts = _shared_prompts(cfg, 32, [4, 4], seed=6)
    eng = _engine(model, params, prefix_share=False)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    eng.run(300)
    assert eng.stats["prefix_hits"] == 0
    assert len(eng.prefix_index) == 0
    assert eng.pool.outstanding == 0


def test_stateful_family_falls_back_to_bookkeeping():
    """Recurrent state is not page-addressable: the trie counts would-be
    hits, but prefill is never skipped and outputs stay deterministic."""
    cfg = REDUCED["falcon-mamba-7b"]
    model = get_model(cfg)
    assert model.supports_paged and not model.supports_prefix_sharing
    params = model.init(jax.random.key(0))
    prompts = _shared_prompts(cfg, 32, [4, 6], seed=7)
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, n_slots=2, max_seq=64, paged=True,
                          page_size=PAGE, prefill_chunk=16)
        reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
        eng.run(300)
        outs.append([tuple(r.generated)
                     for r in sorted(reqs, key=lambda r: r.req_id)])
        assert eng.stats["prefill_tokens_shared"] == 0
        assert eng.stats["prefix_hit_tokens"] >= 32
        assert eng.pool.outstanding == 0
    assert outs[0] == outs[1]
